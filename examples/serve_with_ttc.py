"""Serve a small model with batched requests under TTC-aware admission —
the paper's proportional-fairness (§III) applied to a decode engine.

    PYTHONPATH=src python examples/serve_with_ttc.py
"""

import numpy as np
import jax

from repro.configs import ARCHS
from repro.models import Model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    red = ARCHS["granite-3-2b"].reduced()
    model = Model(red)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, slots=8, max_len=96, eos_id=-1)

    rng = np.random.default_rng(0)
    requests = []
    for i in range(24):
        req = Request(
            rid=i,
            prompt=rng.integers(0, red.vocab, size=4),
            max_new_tokens=int(rng.integers(4, 24)),
            ttc=float(rng.choice([2.0, 10.0, 60.0])))
        requests.append(req)
        engine.submit(req)

    stats = engine.run_until_drained()
    done = [r for r in requests if r.done]
    print(f"served {len(done)}/24 requests in {len(stats)} decode steps "
          f"({engine.clock:.2f}s wall)")
    print(f"Kalman per-token cost estimate: "
          f"{stats[-1].get('per_token_cost', 0) * 1e3:.2f} ms")
    print(f"TTC violations: {engine.ttc_violations(requests)}")
    by_ttc = {}
    for r in requests:
        by_ttc.setdefault(r.ttc, []).append(len(r.generated))
    for ttc in sorted(by_ttc):
        print(f"  ttc={ttc:5.1f}s: {len(by_ttc[ttc])} requests, "
              f"avg {np.mean(by_ttc[ttc]):.1f} tokens")


if __name__ == "__main__":
    main()
