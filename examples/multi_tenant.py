"""Run a multi-tenant CaaS provider on one shared spot fleet.

Four acts:

  1. **Share**: four tenants — each with their own stochastic workload
     world, $/CU-hour price, SLO credit and fair-share weight — run on
     one spot fleet; billing is attributed per tenant and sums exactly
     to the fleet bill.
  2. **Consolidate**: the same four tenants on four dedicated fleets
     (identical workloads, key-for-key); the shared fleet amortizes the
     N_min idle floor and burst headroom.
  3. **Cap**: give one tenant a budget — their arrivals are refused once
     their attributed bill reaches it, instead of running up violations.
  4. **Profit**: tune the provider knobs (`tenant_wg` cross-tenant
     weight tilt, `adm_frac` admission squeeze, `price_mult` list-price
     multiple) for provider profit with the stock CEM tuner — one
     compile for the whole run, never worse than the uniform defaults.

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""

from __future__ import annotations

import jax
import numpy as np

from repro import opt
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, TenantSet,
                       TenantSpec, make_axes, tenants)
from repro.sim.sweep import sweep
from repro.sim.scenarios import MMPP, Diurnal, FlashCrowd, Poisson, TaskModel

SEEDS = (0, 1, 2)


def make_cfg() -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(
            params=ControlParams(monitor_dt=300.0),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=60,
        spot=SpotConfig(enabled=True, instance="m3.xlarge",
                        bid_policy="ttc", bid_mult=1.5,
                        p_spike_per_core=0.02, spike_hours=3.0),
    )


def make_mix(budget_cap: float | None = None) -> TenantSet:
    tm = TaskModel(mean_items=(150.0, 15.0, 100.0, 80.0),
                   items_sigma=0.8, ttc=4500.0)
    common = dict(horizon=20, max_w=16, tasks=tm)
    return TenantSet((
        TenantSpec(Poisson(rate=0.3, **common), price=0.45, weight=1.0),
        TenantSpec(MMPP(rate_lo=0.1, rate_hi=1.0, p_up=0.1, p_down=0.25,
                        **common),
                   price=0.60, slo_penalty=0.5, weight=2.0),
        TenantSpec(Diurnal(rate=0.3, amp=0.8, period=24, **common),
                   price=0.45, weight=1.0,
                   budget=(budget_cap if budget_cap else float("inf"))),
        TenantSpec(FlashCrowd(rate=0.15, spike_rate=2.0, spike_ticks=4,
                              **common),
                   price=0.75, slo_penalty=0.75, weight=1.0),
    ))


def act_1_share(cfg: SimConfig, mix: TenantSet) -> None:
    print("=== 1. four tenants, one spot fleet " + "=" * 30)
    runs = sweep(SweepSpec(axes=make_axes(SEEDS, [1.0]), workload=mix),
                 cfg)
    cost = np.asarray(runs.tenants.cost)           # (seeds, N)
    fleet = np.asarray(runs.fleet.cost_horizon)    # (seeds,)
    for i, name in enumerate(mix.names):
        print(f"  {name:<14} weight={mix[i].weight:.0f}  "
              f"mean bill ${cost[:, i].mean():.4f}  "
              f"violations {np.asarray(runs.tenants.violations)[:, i].sum()}")
    print(f"  fleet bill ${fleet.mean():.4f}; attribution residue "
          f"{np.abs(cost.sum(-1) - fleet).max():.1e} $ "
          "(float display only — integer units sum exactly)")


def act_2_consolidate(cfg: SimConfig, mix: TenantSet) -> None:
    print("=== 2. shared fleet vs four dedicated fleets " + "=" * 21)
    shared = sweep(SweepSpec(axes=make_axes(SEEDS, [1.0]), workload=mix),
                   cfg)
    sh = float(np.mean(np.asarray(shared.fleet.cost_horizon)))
    iso = np.mean([float(np.sum(np.asarray(
        tenants.isolated_runs(mix, cfg, seed=s).cost_horizon)))
        for s in SEEDS])
    print(f"  shared   ${sh:.4f} per run")
    print(f"  isolated ${iso:.4f} per run  "
          f"(consolidation saves {100 * (iso - sh) / iso:.1f}%)")


def act_3_budget(cfg: SimConfig) -> None:
    print("=== 3. budget cap: reject, don't violate " + "=" * 25)
    for cap, label in ((None, "uncapped"), (0.002, "$0.002 cap")):
        mix = make_mix(budget_cap=cap)
        run = tenants.run_tenants(mix, cfg, seed=0)
        i = 2  # the diurnal tenant carries the cap
        print(f"  {label:<10} bill ${float(run.tenants.cost[i]):.4f}  "
              f"rejected {int(run.tenants.rejected[i])}  "
              f"violations {int(run.tenants.violations[i])}")


def act_4_profit(cfg: SimConfig, mix: TenantSet) -> None:
    print("=== 4. provider-profit tuning " + "=" * 36)
    obj = opt.ProfitObjective(cfg, mix, seeds=SEEDS, elasticity=0.5)
    tuning = opt.tune_policy(cfg, None, None, jax.random.PRNGKey(0),
                             objective=obj, pop_size=12, generations=5)
    print(f"  uniform profit ${-float(tuning.default_score):.4f} per run")
    print(f"  tuned   profit ${-float(tuning.result.best_score):.4f} per run"
          f"  (compiled {obj.n_traces}x)")
    for i, name in enumerate(obj.space.names):
        print(f"    {name:<10} {float(np.asarray(tuning.result.best_vec)[i]):.3f}")


def main() -> None:
    cfg = make_cfg()
    mix = make_mix()
    act_1_share(cfg, mix)
    act_2_consolidate(cfg, mix)
    act_3_budget(cfg)
    act_4_profit(cfg, mix)


if __name__ == "__main__":
    main()
