"""Sweep workload *scenarios* as a first-class axis, in one jitted call.

Three stochastic workload worlds (steady Poisson, bursty MMPP, flash
crowd) × two bid policies (static multiple vs TTC-aware) × Monte-Carlo
seeds — every grid point samples its own schedule from (seed, scenario)
inside a single ``sweep(SweepSpec(workload=ScenarioSet, ...))`` dispatch,
then the
per-scenario cost/violation frontier is printed.

Run:  PYTHONPATH=src python examples/scenario_sweep.py
"""

from __future__ import annotations

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (ScenarioSet, SimConfig, SpotConfig, SweepSpec,
                       make_axes)
from repro.sim.sweep import sweep
from repro.sim.scenarios import MMPP, FlashCrowd, Poisson, TaskModel

SEEDS = (0, 1, 2, 3)
POLICIES = ("multiple", "ttc")
BID_MULT = 1.2  # cheap static floor: preemptions happen, TTC-aware escalates


def main() -> None:
    tasks = TaskModel(
        family_weights=(0.3, 0.3, 0.2, 0.2),
        mean_items=(400.0, 40.0, 250.0, 200.0),
        items_sigma=1.0,
        ttc=4500.0,
    )
    common = dict(horizon=30, max_w=64, tasks=tasks)
    sset = ScenarioSet(
        (
            Poisson(rate=1.0, **common),
            MMPP(rate_lo=0.3, rate_hi=3.0, p_up=0.1, p_down=0.25, **common),
            FlashCrowd(rate=0.5, spike_rate=6.0, spike_ticks=4, **common),
        )
    )
    cfg = SimConfig(
        ctrl=ControllerConfig(
            params=ControlParams(monitor_dt=300.0),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=60,
        spot=SpotConfig(
            enabled=True, instance="m3.xlarge", p_spike_per_core=0.02, spike_hours=3.0
        ),
    )

    axes = make_axes(
        seeds=list(SEEDS),
        bid_mults=[BID_MULT],
        instances=["m3.xlarge"],
        policies=list(POLICIES),
        scenarios=sset,
    )
    s = sweep(SweepSpec(axes=axes, workload=sset), cfg)  # one compile,
    # one dispatch, B=24 runs

    shape = (len(SEEDS), len(POLICIES), len(sset))
    cost = np.asarray(s.cost).reshape(shape)
    viol = np.asarray(s.violations).reshape(shape)
    pre = np.asarray(s.preemptions).reshape(shape)

    print(
        f"{len(SEEDS)} seeds x {POLICIES} x {sset.names} "
        f"= {cost.size} simulations, one jitted call\n"
    )
    print(f"{'scenario':10s} {'policy':8s} {'mean $':>8s} {'viol':>5s} {'preempt':>8s}")
    for j, scen in enumerate(sset.names):
        for k, pol in enumerate(POLICIES):
            print(
                f"{scen:10s} {pol:8s} {cost[:, k, j].mean():8.3f} "
                f"{int(viol[:, k, j].sum()):5d} {pre[:, k, j].sum():8.0f}"
            )
        a, b = cost[:, 0, j].mean(), cost[:, 1, j].mean()
        ttc_pt = (int(viol[:, 1, j].sum()), b)
        mult_pt = (int(viol[:, 0, j].sum()), a)
        best = "ttc" if ttc_pt <= mult_pt else "multiple"
        print(f"{'':10s} -> frontier point in this world: {best}")


if __name__ == "__main__":
    main()
