"""End-to-end driver: train a real (reduced) LM for a few hundred steps
under the paper's control plane — AIMD-elastic data parallelism, Kalman
step-cost prediction, spot preemptions, hard failures, stragglers, and
checkpoint/restart on every topology change.

    PYTHONPATH=src python examples/elastic_training.py [--steps 200]
"""

import argparse
import shutil

import jax

from repro.configs import ARCHS
from repro.core.types import ControlParams
from repro.data.pipeline import DataConfig, batch_at
from repro.ft.elastic import ElasticConfig, ElasticTrainer
from repro.ft.failures import FailureConfig, FailureInjector
from repro.models import Model
from repro.training import optimizer
from repro.training.train_loop import init_state, make_train_step

CKPT = "/tmp/repro_elastic_example"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()
    shutil.rmtree(CKPT, ignore_errors=True)

    red = ARCHS[args.arch].reduced()
    model = Model(red)
    state = init_state(model, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"training {red.name}: {n_params / 1e6:.1f}M params "
          f"(reduced {args.arch})")

    opt_cfg = optimizer.OptConfig(lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps)
    step = jax.jit(make_train_step(model, opt_cfg))
    data = DataConfig(vocab=red.vocab, seq_len=64, global_batch=8)

    cfg = ElasticConfig(
        total_steps=args.steps, ttc_seconds=0.4 * args.steps,
        min_replicas=1, max_replicas=16, checkpoint_every=25,
        checkpoint_dir=CKPT,
        control=ControlParams(alpha=2.0, beta=0.9, n_min=1.0, n_max=16.0))
    injector = FailureInjector(FailureConfig(p_fail=2e-3, p_straggle=1e-2,
                                             seed=1))
    trainer = ElasticTrainer(cfg, step, state,
                             lambda s: batch_at(data, s),
                             failures=injector)

    records = trainer.run()
    losses = []
    for r in records:
        if r.step % 25 == 0 or r.event:
            print(f"  step {r.step:4d}  replicas={r.replicas:2d}  "
                  f"step_time={r.step_time:.3f}s  n*={r.n_star:5.2f}  "
                  f"ĉ/step={r.b_hat:.2f} chip-s  {r.event}")

    # verify training actually progressed through all the chaos
    final_loss = float(step(trainer.state, batch_at(data, 0))[1]["loss"])
    print(f"\ncompleted {int(trainer.state.opt.step)} optimizer steps, "
          f"{trainer.restarts} topology changes, final loss {final_loss:.3f}")
    sizes = [r.replicas for r in records]
    print(f"replica count: min {min(sizes)}, max {max(sizes)}; "
          f"job TTC {'met' if trainer.sim_time <= cfg.ttc_seconds else 'MISSED'} "
          f"({trainer.sim_time:.0f}s vs {cfg.ttc_seconds:.0f}s budget)")


if __name__ == "__main__":
    main()
