"""Quickstart: the paper's control plane end-to-end in 60 seconds.

Submits the 30-workload §V.A suite to the simulated CaaS platform, runs the
integrated controller (Kalman CUS prediction → proportional-fair service
rates → AIMD instance scaling) and prints the cost story against the
Autoscale baseline and the 100%-utilization lower bound.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import ControlParams
from repro.sim import SimConfig, paper_schedule, run
from repro.sim.runner import total_cost


def main() -> None:
    params = ControlParams(monitor_dt=300.0)
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    lb = sched.total_cus / 3600 * 0.0081
    print(f"30 workloads, {sched.total_cus:,.0f} CU-seconds of work, "
          f"TTC 125 min each\nlower bound (100% utilization): ${lb:.3f}\n")

    results = {}
    for policy in ("aimd", "reactive", "mwa", "lr", "autoscale"):
        cfg = SimConfig(ctrl=ControllerConfig(policy=policy, params=params,
                                              as_step=10.0), ticks=130)
        tr = run(sched, cfg)
        results[policy] = tr
        c = total_cost(tr)
        print(f"  {policy:10s} cost=${c:.3f}  (+{100 * (c - lb) / lb:5.0f}% "
              f"over LB)  maxN={float(tr.n_committed.max()):3.0f}  "
              f"TTC violations={int(tr.violations)}")

    a = total_cost(results["aimd"])
    s = total_cost(results["autoscale"])
    print(f"\nAIMD saves {100 * (s - a) / s:.0f}% vs Amazon-style Autoscale "
          f"(paper: 38-69%)")

    tr = results["aimd"]
    rel = np.asarray(tr.reliable[:, :, 0])
    t_rel = np.argmax(rel, axis=0) - np.asarray(tr.work_final.t_submit)
    print(f"Kalman time-to-reliable-prediction: "
          f"{np.mean(t_rel[rel.any(0)]) * 5:.0f} min average "
          f"(paper: 9-16 min)")


if __name__ == "__main__":
    main()
