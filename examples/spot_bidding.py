"""Reproduce the paper's spot-market headline (>27% cost reduction) and the
Appendix-A instance-granularity / preemption-rate frontier.

    PYTHONPATH=src python examples/spot_bidding.py

Every experiment below is one jitted ``jax.vmap`` over complete simulations
(market process + billing + preemption + controller), so the whole script is
a handful of XLA dispatches.
"""

import sys

sys.path.insert(0, ".")


def main() -> None:
    from benchmarks import bench_spot

    print("== AIMD-on-spot vs Reactive (paper schedule, 1-min monitoring,")
    print("   fast TTC, immediate termination, on-demand bid) ==")
    hl = bench_spot.run_headline(seeds=(0, 1, 2))
    for policy in ("aimd", "reactive"):
        r = hl[policy]
        print(f"  {policy:10s} ${r['cost']:.3f}   "
              f"violations={r['violations']}  preemptions={r['preemptions']:.0f}")
    print(f"  AIMD saves {hl['saving_pct']:.1f}% of the spot bill "
          "(paper: >27%)")

    print("\n== Bid sweep (3 seeds x 4 bid levels, one vmapped call) ==")
    bid = bench_spot.run_bid_sweep()
    print(f"  {'bid x base':>10s} {'mean $':>8s} {'viol':>5s} {'preempt':>8s}")
    for j, b in enumerate(bid["bid_mults"]):
        print(f"  {b:>10.2f} {bid['cost'][:, j].mean():>8.3f} "
              f"{int(bid['violations'][:, j].sum()):>5d} "
              f"{bid['preemptions'][:, j].sum():>8.0f}")

    print("\n== Granularity frontier (Appendix A Table V, on-demand bid) ==")
    gran = bench_spot.run_granularity()
    print(f"  {'instance':>14s} {'mean $':>8s} {'viol':>5s} {'preempt':>8s} "
          f"{'$/quantum':>10s}")
    for j, name in enumerate(gran["instances"]):
        print(f"  {name:>14s} {gran['cost'][:, j].mean():>8.3f} "
              f"{int(gran['violations'][:, j].sum()):>5d} "
              f"{gran['preemptions'][:, j].sum():>8.0f} "
              f"{gran['mean_price'][:, j].mean():>10.4f}")

    bench_spot.write_csvs(bid, gran)
    print("\nCSVs written to results/spot_bid_sweep.csv / "
          "results/spot_granularity.csv")

    from benchmarks import bench_bidding

    print("\n== Dynamic bid policies on the correlated multi-type market ==")
    print("   (spiky m3.xlarge; static bids must pick cheap-but-violating")
    print("    or safe-but-expensive — state-dependent bids get both)")
    front = bench_bidding.run_policy_frontier(
        seeds=range(6), bid_mults=bench_bidding.SMOKE_MULTS)
    policies = bench_bidding.summarize_policies(front)
    print(f"  {'policy':>10s} {'best bid':>9s} {'mean $':>8s} {'viol':>5s} "
          f"{'vs Reactive':>12s}")
    for name, p in policies.items():
        print(f"  {name:>10s} {p['best_bid_mult']:>9.2f} {p['cost']:>8.3f} "
              f"{p['violations']:>5d} {p['delta_vs_reactive_pct']:>11.1f}%")

    print("\n== Fleet mixes (cheapest-per-CU acquisition, on-demand bid) ==")
    mixes = bench_bidding.run_mix_frontier(seeds=range(6))
    for j, name in enumerate(mixes["names"]):
        print(f"  {name:>10s} ${mixes['cost'][:, j].mean():.3f}  "
              f"violations={int(mixes['violations'][:, j].sum())}  "
              f"preemptions={mixes['preemptions'][:, j].sum():.0f}")


if __name__ == "__main__":
    main()
