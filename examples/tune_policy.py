"""Tune a provisioning policy, then attack it — end to end.

Three acts, each a single jitted optimization run over full simulations:

  1. **Tune**: cross-entropy search over the five ``PolicyParams``
     coefficients (AIMD α/β, relative bid multiple, TTC-escalation gain,
     EMA weight) on a bursty MMPP workload world — every generation's
     whole candidate population is one ``vmap`` through one compiled
     simulation, with the hand-set defaults injected as the incumbent.
  2. **Attack**: freeze the tuned policy and search the MMPP *generator's*
     bounded parameter space for the workload world that hurts it most.
  3. **Robustify**: alternate the two (min–max) and compare the robust
     policy against the plain tuned one on the discovered worst world.

Run:  PYTHONPATH=src python examples/tune_policy.py
"""

from __future__ import annotations

import jax
import numpy as np

from repro import opt
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import ScenarioSet, SimConfig, SpotConfig
from repro.sim.scenarios import MMPP, TaskModel

SEEDS = (0, 1, 2)
PENALTY = 1.0  # $ charged per TTC violation in the tuning score


def make_cfg() -> SimConfig:
    """A market where every tuned coefficient matters: spiky m3.xlarge
    prices, TTC-aware bidding whose floor the market clears above."""
    return SimConfig(
        ctrl=ControllerConfig(
            params=ControlParams(monitor_dt=300.0),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=60,
        spot=SpotConfig(
            enabled=True,
            instance="m3.xlarge",
            bid_policy="ttc",
            bid_mult=1.5,
            p_spike_per_core=0.02,
            spike_hours=3.0,
        ),
    )


def fmt(vec) -> str:
    names = opt.policy_space().names
    return "  ".join(f"{n}={float(np.asarray(vec)[i]):.3f}"
                     for i, n in enumerate(names))


def main() -> None:
    cfg = make_cfg()
    tasks = TaskModel(
        family_weights=(0.3, 0.3, 0.2, 0.2),
        mean_items=(400.0, 40.0, 250.0, 200.0),
        items_sigma=1.0,
        ttc=4500.0,
    )
    spec = MMPP(rate_lo=0.3, rate_hi=3.0, p_up=0.1, p_down=0.25,
                horizon=30, max_w=64, tasks=tasks)
    sset = ScenarioSet((spec,))

    print("== 1. tune the policy on the bursty MMPP world (one jitted CEM)")
    tuning = opt.tune_policy(cfg, sset, seeds=SEEDS,
                             key=jax.random.PRNGKey(0), pop_size=24,
                             generations=6, penalty=PENALTY)
    print(f"  default: score={float(tuning.default_score):.4f}  "
          f"[{fmt(tuning.default_vec)}]")
    print(f"  tuned:   score={float(tuning.result.best_score):.4f}  "
          f"[{fmt(tuning.result.best_vec)}]")
    print(f"  improvement: {tuning.improvement_pct:.1f}%   "
          f"(objective traced {tuning.objective.n_traces}x — one compile)")

    print("== 2. attack the tuned policy (search the generator's box)")
    att = opt.attack_policy(cfg, spec, tuning.params, seeds=SEEDS,
                            key=jax.random.PRNGKey(1), pop_size=16,
                            generations=6, penalty=PENALTY)
    print(f"  nominal world: score={float(att.nominal_score):.4f}")
    print(f"  worst world:   score={float(att.worst_score):.4f}  "
          f"{ {k: round(v, 3) for k, v in att.worst_params.items()} }")

    print("== 3. robustify (min-max: alternate tuning and attack)")
    rob = opt.robust_tune(cfg, spec, seeds=SEEDS,
                          key=jax.random.PRNGKey(2), rounds=2, pop_size=12,
                          generations=4, penalty=PENALTY)
    space = opt.scenario_space(spec)
    tuned_obj = opt.ScenarioObjective(cfg, spec, tuning.params, space,
                                      SEEDS, penalty=PENALTY)
    robust_obj = opt.ScenarioObjective(cfg, spec, rob.params, space,
                                       SEEDS, penalty=PENALTY)

    def score(obj, vec) -> float:
        s = obj.evaluate(vec)
        return float(np.mean(np.asarray(s.cost)
                             + PENALTY * np.asarray(s.violations)))

    on_worst_tuned = score(tuned_obj, att.worst_vec)
    on_worst_robust = score(robust_obj, att.worst_vec)
    print(f"  on the tuned policy's worst world: tuned={on_worst_tuned:.4f}"
          f"  robust={on_worst_robust:.4f}")
    print(f"  robust params: [{fmt(rob.vec)}]")


if __name__ == "__main__":
    main()
